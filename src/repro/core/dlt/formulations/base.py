"""Formulation registry — each paper LP as one pluggable object.

A :class:`Formulation` owns everything the solvers need to know about one
of the paper's programs:

* ``family_dims``       — static LP shape of the padded ``(N_max, M_max)``
  family (variable / inequality-row / equality-row counts),
* ``build_batch_rows``  — the vectorized constraint rows over a
  :class:`~repro.core.dlt.stacking.BatchedSystemSpec` (the ONLY place row
  coefficients are written down — the scalar path derives from it),
* ``batch_column_mask`` — which LP variables are real per scenario,
* ``unpack_batch``      — solution vector -> named schedule fields,
* ``constraint_checks`` — the paper constraint set as labeled vectorized
  predicates, shared by the batch verifier and the scalar verifier.

The scalar entry points (``build_scalar``, ``unpack_scalar``,
``verify_scalar``) are derived on a one-lane batch, so there is exactly
one implementation of every LP row and every constraint check in the
repo, used by the simplex path and the batched interior-point path alike.

Conventions shared by every formulation:

* LP variables are nonnegative and the LAST variable is the objective
  ``T_f`` (minimized);
* inequality rows read ``A_ub x <= b_ub``, equalities ``A_eq x = b_eq``;
* a padded scenario's inactive rows must read ``0 <= 1`` / come with
  ``eq_active=False`` so the standard-form embedding can park them.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Tuple, Union

import numpy as np

from ..stacking import BatchedSystemSpec
from ..types import Schedule, SystemSpec

__all__ = [
    "FamilyDims",
    "BatchRows",
    "BatchFields",
    "BandedStructure",
    "Formulation",
    "register_formulation",
    "get_formulation",
    "available_formulations",
]


class FamilyDims(NamedTuple):
    """Static shape of one padded LP family."""

    nv: int     # LP variables (incl. T_f, the last one)
    n_ub: int   # inequality rows
    n_eq: int   # equality rows

    @property
    def n_rows(self) -> int:
        return self.n_ub + self.n_eq

    @property
    def n_std(self) -> int:
        """Standard-form width: variables + ub slacks + eq artificials."""
        return self.nv + self.n_ub + self.n_eq


class BatchRows(NamedTuple):
    """Stacked constraint rows of a padded family (B leading axis)."""

    A_ub: np.ndarray       # (B, n_ub, nv)
    b_ub: np.ndarray       # (B, n_ub)
    A_eq: np.ndarray       # (B, n_eq, nv)
    b_eq: np.ndarray       # (B, n_eq)
    eq_active: np.ndarray  # (B, n_eq) bool — False on padded eq rows


class BandedStructure(NamedTuple):
    """Block/banded pattern of a formulation's normal equations.

    The paper's programs are transmission-order chains: almost every
    constraint row touches only the variables of one processor column
    ``j`` and its neighbors.  The exceptions are *prefix* rows (source
    1's collapsed ``TF`` chain, Eq 5/Eq 8) and the objective column
    ``T_f`` (every Eq 13 row) — both become local after an exact,
    invertible row transform that replaces each chained row by its
    difference with the previous chain member (a unit-lower-triangular
    ``E``; ``EAx = Eb`` is the same LP).  This tuple records that
    transform plus a row ordering under which ``F D F'`` is
    **block-tridiagonal with a small dense border** (the mass
    conservation row Eq 6/Eq 14), which is what the banded interior
    point kernel factors in O(K s^3) instead of O(m^3).

    Positions below index the *banded row order*; ``perm[t]`` is the
    original row sitting at position ``t``.

    Attributes:
      perm: (n_rows,) original row index at each banded position;
        border rows occupy the trailing positions.
      dprev: (n_rows,) banded position of the row's chain predecessor,
        or -1.  ``dprev[t] = u`` means transformed row ``t`` reads
        ``row[perm[t]] - row[perm[u]]`` (applied once, not iterated);
        each position has at most one successor and predecessors come
        earlier and sit in the same or the previous block.
      block: (n_rows,) block id per position — ``0..n_blocks-1`` for
        band rows (nondecreasing), ``n_blocks`` for border rows.
      n_blocks: number of tridiagonal blocks (one per processor column).
    """

    perm: np.ndarray
    dprev: np.ndarray
    block: np.ndarray
    n_blocks: int

    @property
    def n_rows(self) -> int:
        return int(self.perm.shape[0])

    @property
    def n_border(self) -> int:
        return int(np.sum(self.block == self.n_blocks))

    def successor(self) -> np.ndarray:
        """(n_rows,) the unique chain successor per position, or -1."""
        succ = np.full(self.n_rows, -1, dtype=np.int64)
        has = self.dprev >= 0
        succ[self.dprev[has]] = np.flatnonzero(has)
        return succ

    def validate(self, dims: "FamilyDims") -> None:
        """Structural invariants (cheap; shape-level, not data-level)."""
        m = dims.n_rows
        if sorted(self.perm.tolist()) != list(range(m)):
            raise ValueError("perm is not a permutation of the row set")
        pos = np.arange(m)
        has = self.dprev >= 0
        if np.any(self.dprev[has] >= pos[has]):
            raise ValueError("chain predecessors must come earlier")
        db = self.block[pos[has]] - self.block[self.dprev[has]]
        if np.any((db != 0) & (db != 1)):
            raise ValueError("chain predecessor outside adjacent blocks")
        counts = np.bincount(self.dprev[has], minlength=m)
        if np.any(counts > 1):
            raise ValueError("a position has more than one chain successor")
        band = self.block[self.block < self.n_blocks]
        if band.size and np.any(np.diff(band) < 0):
            raise ValueError("band block ids must be nondecreasing")
        if np.any(self.block[band.size:] != self.n_blocks):
            raise ValueError("border rows must occupy the trailing positions")
        if np.any(has & (self.block == self.n_blocks)):
            raise ValueError("border rows cannot be chain members")


class _BandedBuilder:
    """Row-by-row accumulator the formulations use for banded_structure."""

    def __init__(self):
        self.perm, self.dprev_row, self.block = [], [], []

    def add(self, row: int, block: int, prev_row: int = -1) -> None:
        self.perm.append(row)
        self.dprev_row.append(prev_row)
        self.block.append(block)

    def build(self, n_blocks: int) -> BandedStructure:
        perm = np.asarray(self.perm, dtype=np.int64)
        pos_of = np.empty(perm.size, dtype=np.int64)
        pos_of[perm] = np.arange(perm.size)
        dprev_row = np.asarray(self.dprev_row, dtype=np.int64)
        dprev = np.where(dprev_row >= 0,
                         pos_of[np.maximum(dprev_row, 0)], -1)
        return BandedStructure(
            perm=perm, dprev=dprev,
            block=np.asarray(self.block, dtype=np.int64),
            n_blocks=n_blocks)


@dataclasses.dataclass(frozen=True)
class BatchFields:
    """Named solution fields in the padded (B, N_max, M_max) layout."""

    beta: np.ndarray            # (B, N_max, M_max)
    finish: np.ndarray          # (B,)
    TS: Optional[np.ndarray] = None
    TF: Optional[np.ndarray] = None


class Formulation:
    """Base class: one paper LP formulation, scalar + batched."""

    name: str = ""
    frontend: bool = False        # Schedule semantics (Sec 3.1 vs 3.2)
    has_intervals: bool = False   # unpack produces TS/TF

    # ---- required per-formulation pieces -------------------------------

    def family_dims(self, n_max: int, m_max: int) -> FamilyDims:
        raise NotImplementedError

    def build_batch_rows(self, bs: BatchedSystemSpec) -> BatchRows:
        raise NotImplementedError

    def batch_column_mask(self, bs: BatchedSystemSpec) -> np.ndarray:
        """(B, nv) bool — True on LP variables real for that scenario."""
        raise NotImplementedError

    def unpack_batch(self, bs: BatchedSystemSpec, x: np.ndarray) -> BatchFields:
        """Solution vectors (B, >=nv) -> named fields (padding NOT zeroed)."""
        raise NotImplementedError

    def pack_batch(self, bs: BatchedSystemSpec,
                   fields: BatchFields) -> np.ndarray:
        """Named fields -> LP variable vectors ``(B, nv)``.

        Inverse of :meth:`unpack_batch` on real cells (padded cells may
        land anywhere — callers mask them).  The engine uses this to turn
        a neighboring lane's solution into a warm-start primal for the
        interior-point kernel.
        """
        raise NotImplementedError

    def constraint_checks(self, bs: BatchedSystemSpec, fields: BatchFields,
                          tol: float) -> List[Tuple[str, np.ndarray]]:
        """The paper constraint set as ``[(label, (B,) ok-mask), ...]``.

        Fields must already have exact zeros on padded cells.
        """
        raise NotImplementedError

    # ---- optional: normal-equations structure ---------------------------

    def banded_structure(self, n_max: int,
                         m_max: int) -> Optional[BandedStructure]:
        """Block/banded pattern of this family's normal equations.

        ``None`` (the default) means no structure is known and the
        solver must keep the dense/structured path.  Implementations
        return a :class:`BandedStructure` whose row transform makes
        ``F D F'`` block-tridiagonal-plus-border for EVERY lane of the
        padded family (masked rows only shrink the pattern).
        """
        return None

    # ---- derived: batch verification -----------------------------------

    def verify_batch(self, bs: BatchedSystemSpec, fields: BatchFields,
                     tol: float = 1e-6) -> np.ndarray:
        """(B,) True where every paper constraint holds."""
        ok = ~np.isnan(fields.finish)
        for _, mask in self.constraint_checks(bs, fields, tol):
            ok &= mask
        return ok

    # ---- derived: scalar path (one-lane batch) -------------------------

    def _singleton(self, spec: SystemSpec) -> BatchedSystemSpec:
        return BatchedSystemSpec.from_specs([spec], presorted=True)

    def build_scalar(self, spec: SystemSpec):
        """(c, A_ub, b_ub, A_eq, b_eq) over x >= 0 for an exact-size spec."""
        bs = self._singleton(spec)
        dims = self.family_dims(bs.n_max, bs.m_max)
        rows = self.build_batch_rows(bs)
        c = np.zeros(dims.nv)
        c[dims.nv - 1] = 1.0
        return c, rows.A_ub[0], rows.b_ub[0], rows.A_eq[0], rows.b_eq[0]

    def unpack_scalar(self, spec: SystemSpec, x: np.ndarray) -> Schedule:
        bs = self._singleton(spec)
        f = self.unpack_batch(bs, np.asarray(x)[None, :])
        kw = {}
        if self.has_intervals:
            kw = {"TS": f.TS[0].copy(), "TF": f.TF[0].copy()}
        return Schedule(spec=spec, beta=f.beta[0].copy(),
                        finish_time=float(f.finish[0]),
                        frontend=self.frontend, **kw)

    def verify_scalar(self, sched: Schedule, tol: float = 1e-6) -> list:
        """Violation labels (empty when the schedule satisfies the paper)."""
        return self.verify_scalar_fields(
            sched.spec, sched.beta, sched.finish_time,
            TS=sched.TS, TF=sched.TF, tol=tol)

    def verify_scalar_fields(self, spec: SystemSpec, beta: np.ndarray,
                             finish: float, TS=None, TF=None,
                             tol: float = 1e-6) -> list:
        bs = self._singleton(spec)
        fields = BatchFields(
            beta=np.asarray(beta, dtype=np.float64)[None],
            finish=np.asarray([finish], dtype=np.float64),
            TS=None if TS is None else np.asarray(TS, dtype=np.float64)[None],
            TF=None if TF is None else np.asarray(TF, dtype=np.float64)[None],
        )
        bad = []
        if np.isnan(fields.finish[0]):
            bad.append("finish time is NaN")
        for label, mask in self.constraint_checks(bs, fields, tol):
            if not mask[0]:
                bad.append(f"{label} violated")
        return bad


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Formulation] = {}

FormulationLike = Union[Formulation, str, bool]


def register_formulation(formulation: Formulation) -> Formulation:
    """Register a formulation instance under its ``name``."""
    if not formulation.name:
        raise ValueError("formulation needs a non-empty name")
    _REGISTRY[formulation.name] = formulation
    return formulation


def get_formulation(which: FormulationLike) -> Formulation:
    """Resolve a formulation: instance, registry name, or legacy bool.

    ``True`` / ``False`` map to the paper's Sec 3.1 front-end / Sec 3.2
    no-front-end programs (the pre-registry API surface).
    """
    if isinstance(which, Formulation):
        return which
    if isinstance(which, (bool, np.bool_)):
        return _REGISTRY["frontend" if which else "nofrontend"]
    if isinstance(which, str):
        try:
            return _REGISTRY[which]
        except KeyError:
            raise KeyError(
                f"unknown formulation {which!r}; available: "
                f"{available_formulations()}") from None
    raise TypeError(f"cannot resolve formulation from {which!r}")


def available_formulations() -> list:
    return sorted(_REGISTRY)
