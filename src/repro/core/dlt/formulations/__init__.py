"""Formulation registry for the DLT scenario families.

Every LP formulation — the paper's Sec 3.1 front-end, Sec 3.2
no-front-end and its column-reduced chain variant, plus the related-work
scenario families (resource-sharing networks, multi-installment bus
scheduling) — is one :class:`Formulation` object exposing scalar builds,
batched row builds, unpacking, verification and a declared
:class:`FormulationCapabilities` record.  The scalar simplex path and
the batched interior-point engine share these objects, so each LP row
and each paper constraint is written down exactly once.

Third-party formulations plug in through :func:`register`; the engine
and dltlint consult ``capabilities`` (never formulation names), so a
registered formulation gets kernel routing, bucketing, warm sweeps and
lint coverage without engine changes — see CONTRIBUTING's "Authoring a
formulation" guide.

>>> from repro.core.dlt.formulations import get_formulation
>>> get_formulation("nofrontend_reduced").family_dims(2, 8)
FamilyDims(nv=25, n_ub=25, n_eq=1)
"""

from .base import (
    DEFAULT_NOFRONTEND_FORMULATION,
    BandedStructure,
    BatchFields,
    BatchRows,
    FamilyDims,
    Formulation,
    FormulationCapabilities,
    available_formulations,
    default_batched_formulation,
    get_formulation,
    register,
    register_formulation,
)
from .frontend import FRONTEND, FrontendFormulation
from .multi_installment import MULTI_INSTALLMENT, MultiInstallmentFormulation
from .nofrontend import NOFRONTEND, NoFrontendFormulation
from .nofrontend_reduced import NOFRONTEND_REDUCED, ReducedNoFrontendFormulation
from .resource_sharing import RESOURCE_SHARING, ResourceSharingFormulation

__all__ = [
    "Formulation",
    "FormulationCapabilities",
    "FamilyDims",
    "BatchRows",
    "BatchFields",
    "BandedStructure",
    "register",
    "register_formulation",
    "get_formulation",
    "available_formulations",
    "default_batched_formulation",
    "DEFAULT_NOFRONTEND_FORMULATION",
    "FrontendFormulation",
    "NoFrontendFormulation",
    "ReducedNoFrontendFormulation",
    "ResourceSharingFormulation",
    "MultiInstallmentFormulation",
    "FRONTEND",
    "NOFRONTEND",
    "NOFRONTEND_REDUCED",
    "RESOURCE_SHARING",
    "MULTI_INSTALLMENT",
]
