"""Formulation registry for the paper's DLT programs.

Every LP formulation — Sec 3.1 front-end, Sec 3.2 no-front-end, and the
column-reduced no-front-end chain variant — is one :class:`Formulation`
object exposing scalar builds, batched row builds, unpacking, and
verification.  The scalar simplex path and the batched interior-point
engine share these objects, so each LP row and each paper constraint is
written down exactly once.

>>> from repro.core.dlt.formulations import get_formulation
>>> get_formulation("nofrontend_reduced").family_dims(2, 8)
FamilyDims(nv=25, n_ub=25, n_eq=1)
"""

from .base import (
    BandedStructure,
    BatchFields,
    BatchRows,
    FamilyDims,
    Formulation,
    available_formulations,
    get_formulation,
    register_formulation,
)
from .frontend import FRONTEND, FrontendFormulation
from .nofrontend import NOFRONTEND, NoFrontendFormulation
from .nofrontend_reduced import NOFRONTEND_REDUCED, ReducedNoFrontendFormulation

__all__ = [
    "Formulation",
    "FamilyDims",
    "BatchRows",
    "BatchFields",
    "BandedStructure",
    "register_formulation",
    "get_formulation",
    "available_formulations",
    "FrontendFormulation",
    "NoFrontendFormulation",
    "ReducedNoFrontendFormulation",
    "FRONTEND",
    "NOFRONTEND",
    "NOFRONTEND_REDUCED",
]
