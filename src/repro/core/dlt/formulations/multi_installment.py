"""Multi-installment scheduling LP — R-round distribution on a bus network.

Implements the multi-installment divisible-load model of
Berlinska/Drozdowski-style linear/bus networks (arXiv:0706.4038): ONE
source feeds M processors over a shared bus in R rounds ("installments").
Round-robin order — installment ``(r, j)`` is the ``q = r*M + j``-th
transmission on the bus — so a processor starts computing early chunks
while later chunks are still in flight, which is the whole point of
multi-installment distribution: it hides communication latency that a
single-installment schedule must serialize.

Per-spec extras: ``installments`` (R, a positive integer).  R buckets
exactly like the processor count M does — lanes group by
``bucket(R)`` and the padded family is built at the bucket edge, so a
mixed-R batch compiles one executable per (bucket_M, bucket_R) pair.

Variables (installment-major order ``q = r*M + j``):
    x = [beta (R*M), F (R*M), T_f]        all >= 0

``beta[r, j]`` is the load of installment ``(r, j)``; ``F[r, j]`` its
computation-finish time on ``P_j``.  With ``G`` the bus inverse speed,
``R_1`` the source release time and arrival time
``T_arr(r,j) = R_1 + G * sum_{q' <= q} beta[q']`` (bus serialization):

  (EqA)  F_{r,j} >= T_arr(r,j) + A_j beta_{r,j}       (arrive, then compute)
  (EqQ)  F_{r,j} >= F_{r-1,j} + A_j beta_{r,j}        (per-processor queue)
  (EqT)  T_f    >= F_{r,j}                            (makespan)
  (EqM)  sum beta = J                                 (mass)

i.e. ``(3R-1)M`` inequality rows and one equality.  At R = 1 this IS
the paper's Sec 2 single-source no-front-end program.  No banded
structure is declared: the EqA prefix sums are dense across EVERY
installment column and there is no per-column diff that cancels them
(adjacent q differ by a full A_j swap), so the formulation declares
itself structureless and the engine routes it to the structured/dense
kernels.

Unlike the grid formulations, ``build_batch_rows`` masks padded CELLS
in its own coefficients (not just through the downstream column mask),
so the scalar simplex path may solve a round-padded family directly.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from ..stacking import BatchedSystemSpec
from ..types import Schedule, SystemSpec
from .base import (
    BatchFields,
    BatchRows,
    FamilyDims,
    Formulation,
    FormulationCapabilities,
    register,
)

__all__ = ["MultiInstallmentFormulation", "MULTI_INSTALLMENT",
           "R_BUCKET_EDGES"]

#: Installment-count bucket edges (same ladder the M-axis uses).
R_BUCKET_EDGES: Tuple[int, ...] = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32)


def _bucket_r(r: int) -> int:
    for edge in R_BUCKET_EDGES:
        if r <= edge:
            return edge
    return int(r)


class MultiInstallmentFormulation(Formulation):
    """R-round bus LP: ``x = [beta (R*M), F (R*M), T_f]`` (single source)."""

    name = "multi_installment"
    frontend = False
    has_intervals = False
    capabilities = FormulationCapabilities(
        supports_banded=False,
        supports_warm_transfer=False,
        oracle_kind="self",
        spec_axes=("m", "installments"),
    )

    # ---- shape plumbing -------------------------------------------------

    def family_dims(self, n_max: int, m_max: int) -> FamilyDims:
        """Dims at ``n_max`` INSTALLMENTS (the R axis rides the n slot).

        This formulation is single-source; its family shape varies over
        (R, M), so the registry-wide ``(n_max, m_max)`` signature is
        reinterpreted with the installment bucket in the first slot
        (``batch_dims`` is the canonical entry point and does exactly
        that).
        """
        Rm, M = n_max, m_max
        return FamilyDims(
            nv=2 * Rm * M + 1,
            n_ub=(3 * Rm - 1) * M,
            n_eq=1,
        )

    def _installments(self, bs: BatchedSystemSpec) -> np.ndarray:
        r = self._extra(bs, "installments")
        ri = np.rint(r)
        if np.any(np.abs(r - ri) > 0) or np.any(ri < 1):
            raise ValueError("installments must be integers >= 1")
        return ri.astype(np.int64)

    def batch_dims(self, bs: BatchedSystemSpec) -> FamilyDims:
        Rm = _bucket_r(int(self._installments(bs).max()))
        return self.family_dims(Rm, bs.m_max)

    def group_key(self, bs: BatchedSystemSpec, k: int) -> tuple:
        return (_bucket_r(int(self._installments(bs)[k])),)

    def _round_mask(self, bs: BatchedSystemSpec, Rm: int) -> np.ndarray:
        """(B, Rm, M) — True on real (installment, processor) cells."""
        rk = self._installments(bs)
        ract = np.arange(Rm)[None, :] < rk[:, None]
        return ract[:, :, None] & bs.proc_mask[:, None, :]

    # ---- LP pieces ------------------------------------------------------

    def batch_column_mask(self, bs: BatchedSystemSpec) -> np.ndarray:
        dims = self.batch_dims(bs)
        Rm = (dims.nv - 1) // (2 * bs.m_max)
        cell = self._round_mask(bs, Rm).reshape(bs.batch, -1)
        return np.concatenate(
            [cell, cell, np.ones((bs.batch, 1), dtype=bool)], axis=1)

    def build_batch_rows(self, bs: BatchedSystemSpec) -> BatchRows:
        """EqA/EqQ/EqT/EqM rows, cell-masked in the coefficients."""
        if bs.n_max != 1:
            raise ValueError(
                "multi_installment models a single source; got a family "
                f"with n_max={bs.n_max} (it declares spec_axes "
                f"{self.capabilities.spec_axes} — no 'n' axis)")
        B, M = bs.batch, bs.m_max
        dims = self.batch_dims(bs)
        Rm = (dims.nv - 1) // (2 * M)
        RM = Rm * M
        nv, n_ub = dims.nv, dims.n_ub
        tf = nv - 1
        G0, R0, A, J = bs.G[:, 0], bs.R[:, 0], bs.A, bs.J
        act = self._round_mask(bs, Rm).reshape(B, RM)         # (B, RM)
        qc = np.arange(RM)
        jq = qc % M                                           # processor of q

        A_ub = np.zeros((B, n_ub, nv))
        b_ub = np.zeros((B, n_ub))

        # (EqA)  G sum_{q'<=q} beta + A_j beta_q - F_q <= -R_1,  RM rows
        oA = 0
        tri_incl = (qc[:, None] >= qc[None, :]).astype(float)  # q' <= q
        A_ub[:, oA: oA + RM, :RM] = (
            G0[:, None, None] * tri_incl[None] * act[:, None, :])
        A_ub[:, oA + qc, qc] += A[:, jq]
        A_ub[:, oA + qc, RM + qc] = -1.0
        A_ub[:, oA: oA + RM] *= act[:, :, None]
        b_ub[:, oA + qc] = np.where(act, -R0[:, None], 1.0)

        # (EqQ)  F_{r-1,j} + A_j beta_{r,j} - F_{r,j} <= 0,  (Rm-1)*M rows
        oQ = RM
        if Rm > 1:
            q1 = np.arange(M, RM)                 # cells with a prior round
            r = oQ + np.arange(q1.size)
            actq = act[:, q1]
            A_ub[:, r, RM + q1 - M] = np.where(actq, 1.0, 0.0)
            A_ub[:, r, q1] = np.where(actq, A[:, q1 % M], 0.0)
            A_ub[:, r, RM + q1] = np.where(actq, -1.0, 0.0)
            b_ub[:, r] = np.where(actq, 0.0, 1.0)

        # (EqT)  F_q - T_f <= 0,  RM rows
        oT = oQ + (Rm - 1) * M
        A_ub[:, oT + qc, RM + qc] = np.where(act, 1.0, 0.0)
        A_ub[:, oT + qc, tf] = np.where(act, -1.0, 0.0)
        b_ub[:, oT + qc] = np.where(act, 0.0, 1.0)

        # (EqM)  sum beta = J  (cell-masked, so scalar padding is inert)
        A_eq = np.zeros((B, 1, nv))
        A_eq[:, 0, :RM] = act.astype(float)
        b_eq = J[:, None].copy()
        eq_active = np.ones((B, 1), dtype=bool)
        return BatchRows(A_ub, b_ub, A_eq, b_eq, eq_active)

    def unpack_batch(self, bs: BatchedSystemSpec, x: np.ndarray) -> BatchFields:
        """Fields: per-processor totals in ``beta``, rounds in ``extra``."""
        B, M = bs.batch, bs.m_max
        dims = self.batch_dims(bs)
        Rm = (dims.nv - 1) // (2 * M)
        RM = Rm * M
        if x.shape[1] not in (dims.nv, dims.n_std):
            raise ValueError(
                f"solution width {x.shape[1]} matches neither nv={dims.nv} "
                f"nor n_std={dims.n_std} of the R-bucketed family — lanes "
                "from different installment buckets cannot share a batch")
        beta_r = x[:, :RM].reshape(B, Rm, M).copy()
        F_r = x[:, RM: 2 * RM].reshape(B, Rm, M).copy()
        return BatchFields(
            beta=beta_r.sum(axis=1, keepdims=True),
            finish=x[:, 2 * RM].copy(),
            extra={"beta_r": beta_r, "F_r": F_r},
        )

    def pack_batch(self, bs: BatchedSystemSpec,
                   fields: BatchFields) -> np.ndarray:
        B = bs.batch
        if not fields.extra or "beta_r" not in fields.extra:
            raise ValueError(
                "multi_installment pack_batch needs the per-round fields "
                "(extra['beta_r'] / extra['F_r']) produced by unpack_batch")
        return np.concatenate(
            [fields.extra["beta_r"].reshape(B, -1),
             fields.extra["F_r"].reshape(B, -1),
             fields.finish[:, None]], axis=1)

    # ---- verification ---------------------------------------------------

    def _implied_finish(self, bs: BatchedSystemSpec, beta_r: np.ndarray,
                        act: np.ndarray):
        """Minimal feasible per-cell finish + its per-lane max.

        The bus recursion from the rounds alone:
        ``F(r,j) = max(T_arr(r,j), F(r-1,j)) + A_j beta_{r,j}`` — the LP
        optimum satisfies ``T_f >= max F`` and any schedule violating it
        is infeasible, so verification never needs the LP's F block.
        """
        B, Rb, M = beta_r.shape
        G0, R0, A = bs.G[:, 0], bs.R[:, 0], bs.A[:, :M]
        pref = np.cumsum(beta_r.reshape(B, Rb * M), axis=1).reshape(B, Rb, M)
        arr = R0[:, None, None] + G0[:, None, None] * pref
        prevF = np.zeros((B, M))
        maxF = np.zeros(B)
        for r in range(Rb):
            a = act[:, r, :]
            f = np.maximum(arr[:, r, :], prevF) + A * beta_r[:, r, :]
            prevF = np.where(a, f, prevF)
            maxF = np.maximum(maxF, np.max(np.where(a, f, 0.0), axis=1))
        return maxF

    def _rounds_of(self, bs: BatchedSystemSpec,
                   fields: BatchFields) -> np.ndarray:
        """(B, Rb, M) per-round loads from extra (or scalar-path beta)."""
        if fields.extra and "beta_r" in fields.extra:
            return np.asarray(fields.extra["beta_r"], dtype=np.float64)
        # scalar verify path: Schedule.beta IS the (R, M) round matrix
        return np.asarray(fields.beta, dtype=np.float64)

    def constraint_checks(self, bs: BatchedSystemSpec, fields: BatchFields,
                          tol: float) -> List[Tuple[str, np.ndarray]]:
        beta_r = self._rounds_of(bs, fields)
        finish = fields.finish
        Rb = beta_r.shape[1]
        act = self._round_mask(bs, Rb)
        scale = np.maximum(1.0, np.maximum(np.nan_to_num(finish), bs.J))
        slack = tol * scale
        checks = []
        checks.append(("beta >= 0", ~np.any(
            (beta_r < -slack[:, None, None]) & act, axis=(1, 2))))
        checks.append(("EqM (mass = J)", np.abs(
            beta_r.sum(axis=(1, 2)) - bs.J) <= slack))
        need = self._implied_finish(bs, np.where(act, beta_r, 0.0), act)
        checks.append(("EqA/EqQ/EqT (bus arrival + sequential compute)",
                       finish >= need - slack))
        return checks

    # ---- engine hooks ---------------------------------------------------

    def clean_batch(self, bs: BatchedSystemSpec,
                    fields: BatchFields) -> BatchFields:
        """Exact zeros on padded rounds/processors; totals recomputed."""
        if not fields.extra or "beta_r" not in fields.extra:
            return super().clean_batch(bs, fields)
        beta_r = fields.extra["beta_r"]
        act = self._round_mask(bs, beta_r.shape[1])
        beta_r = np.where(act, beta_r, 0.0)
        F_r = np.where(act, fields.extra["F_r"], 0.0)
        return BatchFields(
            beta=beta_r.sum(axis=1, keepdims=True),
            finish=fields.finish, TS=None, TF=None,
            extra={"beta_r": beta_r, "F_r": F_r},
        )

    def warm_fields(self, bs_dest: BatchedSystemSpec,
                    fields_src: BatchFields,
                    cell_src: np.ndarray) -> BatchFields:
        """Round-level warm seed: renormalize, then re-chain the finishes."""
        if not fields_src.extra or "beta_r" not in fields_src.extra:
            raise ValueError(
                "multi_installment warm seeding needs per-round source "
                "fields (extra['beta_r'])")
        beta_r = np.asarray(fields_src.extra["beta_r"], dtype=np.float64)
        act = self._round_mask(bs_dest, beta_r.shape[1])
        beta_r = np.where(act, beta_r, 0.0)
        tot = beta_r.sum(axis=(1, 2))
        beta_r *= np.where(tot > 0, bs_dest.J / np.where(tot > 0, tot, 1.0),
                           1.0)[:, None, None]
        B, Rb, M = beta_r.shape
        G0, R0, A = bs_dest.G[:, 0], bs_dest.R[:, 0], bs_dest.A[:, :M]
        pref = np.cumsum(beta_r.reshape(B, Rb * M), axis=1).reshape(B, Rb, M)
        arr = R0[:, None, None] + G0[:, None, None] * pref
        F_r = np.zeros((B, Rb, M))
        prevF = np.zeros((B, M))
        finish = np.zeros(B)
        for r in range(Rb):
            a = act[:, r, :]
            f = np.maximum(arr[:, r, :], prevF) + A * beta_r[:, r, :]
            F_r[:, r, :] = np.where(a, f, 0.0)
            prevF = np.where(a, f, prevF)
            finish = np.maximum(finish, np.max(np.where(a, f, 0.0), axis=1))
        return BatchFields(
            beta=beta_r.sum(axis=1, keepdims=True), finish=finish,
            extra={"beta_r": beta_r, "F_r": F_r},
        )

    def fold_schedule(self, sched: Schedule) -> np.ndarray:
        """Scalar schedules carry rounds; the grid wants per-proc totals."""
        return np.asarray(sched.beta, dtype=np.float64).sum(
            axis=0, keepdims=True)

    def demo_batch(self, n: int = 2, m: int = 3,
                   masked: bool = True) -> BatchedSystemSpec:
        """Single-source demo; the requested ``n`` rides the R axis."""
        shapes = [(n, m)]
        if masked:
            shapes.append((max(1, n - 1), max(1, m - 1)))
        specs = []
        for li, (rl, ml) in enumerate(shapes):
            if li == 0:
                specs.append(SystemSpec(
                    G=[0.2], R=[0.0], A=1.0 + 0.25 * np.arange(ml),
                    J=10.0 + rl + ml, extras={"installments": rl}))
            else:
                specs.append(SystemSpec(
                    G=[0.3], R=[0.0], A=1.5 + 0.5 * np.arange(ml),
                    J=5.0, extras={"installments": rl}))
        return BatchedSystemSpec.from_specs(specs)

    # ---- scalar path ----------------------------------------------------

    def unpack_scalar(self, spec: SystemSpec, x: np.ndarray) -> Schedule:
        """Schedule.beta is the per-round (R, M) installment matrix."""
        bs = self._singleton(spec)
        f = self.unpack_batch(bs, np.asarray(x)[None, :])
        rk = int(self._installments(bs)[0])
        return Schedule(spec=spec, beta=f.extra["beta_r"][0, :rk, :].copy(),
                        finish_time=float(f.finish[0]), frontend=False)


MULTI_INSTALLMENT = register(MultiInstallmentFormulation())
