"""Resource-sharing network LP — sources share one bottleneck link.

Extends the Sec 3.1 front-end program with the shared-link capacity
coupling of Wu/Cao/Robertazzi, "Optimal Scheduling for Divisible Loads
in Resource-Sharing Networks" (arXiv:1902.01898): the sources do not
own independent channels — their transmissions ride ONE shared bus
whose inverse capacity is the per-spec extra ``link_capacity``
(time / unit load; ``0`` models an uncontended network and reduces the
program to the plain front-end LP).

Because transmissions are serialized on the bus in processor order, the
load destined to processors ``1..j`` (from every source) must clear the
shared link before ``P_j``'s pipeline can drain, which adds one coupling
row per processor to the front-end program:

  (EqL)  R_1 + ell * sum_{i, k<=j} beta_{i,k} <= T_f        j = 1..M

Variables are unchanged: ``x = [beta (N*M), T_f]``.  The EqL rows
couple EVERY source's beta across a processor prefix, so they are dense
in the processor-block basis — they live in the arrowhead BORDER of the
banded structure next to the Eq 6 mass row (the sparsity claim is
property-checked by dltlint's DL005 symbolic rule).  The Eq 6 row stays
FIRST among the border rows: cross-bucket warm transfer matches border
rows by index, and Eq 6 is the row every bucket shares.
"""

from __future__ import annotations

import numpy as np

from ..stacking import BatchedSystemSpec
from .base import (
    BandedStructure,
    BatchFields,
    BatchRows,
    FamilyDims,
    FormulationCapabilities,
    _BandedBuilder,
    register,
)
from .frontend import FrontendFormulation

__all__ = ["ResourceSharingFormulation", "RESOURCE_SHARING"]


class ResourceSharingFormulation(FrontendFormulation):
    """Front-end LP + shared-link prefix rows: ``x = [beta (N*M), T_f]``."""

    name = "resource_sharing"
    frontend = True
    has_intervals = False
    capabilities = FormulationCapabilities(
        supports_banded=True,
        supports_warm_transfer=True,
        oracle_kind="self",
        spec_axes=("n", "m", "link_capacity"),
    )

    def family_dims(self, n_max: int, m_max: int) -> FamilyDims:
        N, M = n_max, m_max
        return FamilyDims(
            nv=N * M + 1,
            n_ub=(N - 1) + (N - 1) * (M - 1) + M + M,   # front-end + EqL
            n_eq=1,
        )

    def _link(self, bs: BatchedSystemSpec) -> np.ndarray:
        ell = self._extra(bs, "link_capacity")
        if np.any(ell < 0):
            raise ValueError("link_capacity must be >= 0 "
                             "(inverse shared-link speed)")
        return ell

    def build_batch_rows(self, bs: BatchedSystemSpec) -> BatchRows:
        """Front-end rows (Eqs 3-6) + the M shared-link prefix rows."""
        rows = super().build_batch_rows(bs)
        B, N, M = bs.batch, bs.n_max, bs.m_max
        ell = self._link(bs)
        ms = bs.n_procs[:, None]
        tf = N * M
        oL = (N - 1) + (N - 1) * (M - 1) + M
        jc = np.arange(M)
        act = jc[None, :] < ms

        # (EqL)  ell * sum_{i, k<=j} beta_{i,k} - T_f <= -R_1
        A_ub, b_ub = rows.A_ub, rows.b_ub
        tri_incl = (jc[:, None] >= jc[None, :]).astype(float)   # k <= j
        A_ub[:, oL: oL + M, :tf] = (
            ell[:, None, None] * np.tile(tri_incl, (1, N))[None])
        A_ub[:, oL + jc, tf] = -1.0
        A_ub[:, oL: oL + M] *= act[:, :, None]
        b_ub[:, oL + jc] = np.where(act, -bs.R[:, :1], 1.0)
        return rows

    def banded_structure(self, n_max: int, m_max: int) -> BandedStructure:
        """Front-end chain blocks; EqL joins the arrowhead border.

        Same block layout as the front-end program (Eq 3 in block 0,
        Eq 5 as a diff chain, Eq 4 coupling ``j-1`` to ``j``).  The EqL
        prefix rows are dense across processor columns and CANNOT be
        localized by a diff against Eq 5 (different A_j weights), so
        they sit in the border with the Eq 6 mass row — Eq 6 first, so
        border-by-index row transfer pairs the row every bucket shares.
        """
        N, M = n_max, m_max
        dims = self.family_dims(N, M)
        o4 = N - 1
        o5 = (N - 1) + (N - 1) * (M - 1)
        oL = o5 + M
        sb = _BandedBuilder()
        for j in range(M):
            if j == 0:
                for i in range(N - 1):                       # Eq 3
                    sb.add(i, 0)
            sb.add(o5 + j, j, o5 + j - 1 if j else -1)       # Eq 5 (diff)
            if j >= 1:
                for i in range(N - 1):                       # Eq 4 (i, j-1)
                    sb.add(o4 + i * (M - 1) + (j - 1), j)
        sb.add(dims.n_ub, M)                                 # Eq 6 border
        for j in range(M):                                   # EqL border
            sb.add(oL + j, M)
        return sb.build(M)

    def constraint_checks(self, bs: BatchedSystemSpec, fields: BatchFields,
                          tol: float):
        """Eqs 3-6 + the shared-link prefix bound (padded cells zero)."""
        checks = super().constraint_checks(bs, fields, tol)
        ell = self._link(bs)
        beta, finish = fields.beta, fields.finish
        scale = np.maximum(1.0, np.maximum(np.nan_to_num(finish), bs.J))
        slack = tol * scale
        pref = np.cumsum(beta.sum(axis=1), axis=1)           # (B, M) k <= j
        need = bs.R[:, :1] + ell[:, None] * pref
        checks.append(("EqL (shared link)", ~np.any(
            bs.proc_mask & (finish[:, None] < need - slack[:, None]),
            axis=1)))
        return checks


RESOURCE_SHARING = register(ResourceSharingFormulation())
