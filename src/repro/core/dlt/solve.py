"""Unified solve API for the paper's DLT programs.

``solve(spec, frontend=...)`` canonicalizes node order (G ascending, A
ascending — paper Sec 3 sorting rule), builds the requested formulation
from the registry (:mod:`repro.core.dlt.formulations` — Sec 3.1, Sec 3.2,
or the column-reduced Sec 3.2 chain variant), solves it with the
self-contained simplex (or scipy/HiGHS when requested), verifies every
paper constraint on the result, and returns a
:class:`~repro.core.dlt.types.Schedule` in canonical order.
"""

from __future__ import annotations

from typing import Literal, Union

import numpy as np

from .formulations import Formulation, get_formulation
from .simplex import linprog_simplex
from .single_source import solve_single_source
from .types import InfeasibleError, Schedule, SystemSpec

__all__ = ["solve", "verify_schedule"]

Solver = Literal["simplex", "highs", "auto"]


def _run_lp(c, A_ub, b_ub, A_eq, b_eq, solver: Solver):
    if solver in ("highs", "auto"):
        try:
            from scipy.optimize import linprog  # local import: optional dep

            res = linprog(c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq, method="highs")
            if res.status == 2:
                raise InfeasibleError("DLT program infeasible (HiGHS)")
            if not res.success:
                raise RuntimeError(f"HiGHS failed: {res.message}")
            return np.asarray(res.x)
        except ImportError:
            if solver == "highs":
                raise
    res = linprog_simplex(c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq)
    if res.status == 2:
        raise InfeasibleError("DLT program infeasible (simplex)")
    if not res.success:
        raise RuntimeError(f"simplex failed: {res.message}")
    return res.x


def solve(
    spec: SystemSpec,
    frontend: bool = True,
    solver: Solver = "auto",
    verify: bool = True,
    presorted: bool = False,
    formulation: "Union[Formulation, str, None]" = None,
) -> Schedule:
    """Minimal-makespan schedule for a multi-source multi-processor system.

    Args:
      spec: the system (G, R, A, [C], J).
      frontend: True -> Sec 3.1 LP (compute overlaps receive);
                False -> Sec 3.2 LP (compute after full receive).
      solver: "simplex" (self-contained), "highs" (scipy), or "auto".
      verify: re-check every paper constraint on the solution.
      presorted: skip canonical sorting (inputs already G-/A-ascending).
      formulation: registry name or :class:`Formulation` overriding
        ``frontend`` — e.g. ``"nofrontend_reduced"`` pins the
        column-reduced Sec 3.2 program.  When omitted, the classic
        mapping applies (``"frontend"`` / ``"nofrontend"``), keeping this
        path the independent oracle for the batched engine's reduced
        default.
    """
    cspec = spec if presorted else spec.canonical()[0]
    if formulation is not None:
        fm = get_formulation(formulation)
        frontend = fm.frontend
    else:
        if cspec.num_sources == 1 and not frontend:
            # Sec 2 closed form — also serves as an LP cross-check in tests.
            return solve_single_source(cspec, frontend=False)
        fm = get_formulation(frontend)

    c, A_ub, b_ub, A_eq, b_eq = fm.build_scalar(cspec)
    x = _run_lp(c, A_ub, b_ub, A_eq, b_eq, solver)
    sched = fm.unpack_scalar(cspec, x)
    if verify:
        bad = fm.verify_scalar(sched)
        if bad:
            raise RuntimeError(
                f"{fm.name} solution violates constraints: {bad[:3]}")
    return sched


def verify_schedule(sched: Schedule, tol: float = 1e-6) -> list:
    """Re-validate a schedule against the paper's constraint set."""
    if sched.frontend:
        return get_formulation("frontend").verify_scalar(sched, tol)
    if sched.TS is None or sched.TF is None:
        # closed-form single-source schedule: check Eq 1/2 directly
        spec = sched.spec
        G, A, J = float(spec.G[0]), spec.A, spec.J
        beta = sched.beta[0]
        bad = []
        if abs(beta.sum() - J) > tol * max(1.0, J):
            bad.append("Eq2 violated")
        for i in range(spec.num_processors):
            tf_i = float(spec.R[0]) + beta[: i + 1].sum() * G + beta[i] * A[i]
            if abs(tf_i - sched.finish_time) > tol * max(1.0, sched.finish_time):
                bad.append(f"Eq1 violated at i={i}")
        return bad
    return get_formulation("nofrontend").verify_scalar(sched, tol)
