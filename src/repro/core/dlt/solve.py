"""Unified solve API for the paper's DLT programs.

``solve(spec, frontend=...)`` canonicalizes node order (G ascending, A
ascending — paper Sec 3 sorting rule), builds the Sec 3.1 or Sec 3.2 LP,
solves it with the self-contained simplex (or scipy/HiGHS when requested),
verifies every paper constraint on the result, and returns a
:class:`~repro.core.dlt.types.Schedule` in canonical order.
"""

from __future__ import annotations

from typing import Literal

import numpy as np

from .frontend_lp import build_frontend_lp, unpack_frontend, verify_frontend
from .nofrontend_lp import build_nofrontend_lp, unpack_nofrontend, verify_nofrontend
from .simplex import linprog_simplex
from .single_source import solve_single_source
from .types import InfeasibleError, Schedule, SystemSpec

__all__ = ["solve", "verify_schedule"]

Solver = Literal["simplex", "highs", "auto"]


def _run_lp(c, A_ub, b_ub, A_eq, b_eq, solver: Solver):
    if solver in ("highs", "auto"):
        try:
            from scipy.optimize import linprog  # local import: optional dep

            res = linprog(c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq, method="highs")
            if res.status == 2:
                raise InfeasibleError("DLT program infeasible (HiGHS)")
            if not res.success:
                raise RuntimeError(f"HiGHS failed: {res.message}")
            return np.asarray(res.x)
        except ImportError:
            if solver == "highs":
                raise
    res = linprog_simplex(c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq)
    if res.status == 2:
        raise InfeasibleError("DLT program infeasible (simplex)")
    if not res.success:
        raise RuntimeError(f"simplex failed: {res.message}")
    return res.x


def solve(
    spec: SystemSpec,
    frontend: bool = True,
    solver: Solver = "auto",
    verify: bool = True,
    presorted: bool = False,
) -> Schedule:
    """Minimal-makespan schedule for a multi-source multi-processor system.

    Args:
      spec: the system (G, R, A, [C], J).
      frontend: True -> Sec 3.1 LP (compute overlaps receive);
                False -> Sec 3.2 LP (compute after full receive).
      solver: "simplex" (self-contained), "highs" (scipy), or "auto".
      verify: re-check every paper constraint on the solution.
      presorted: skip canonical sorting (inputs already G-/A-ascending).
    """
    cspec = spec if presorted else spec.canonical()[0]

    if cspec.num_sources == 1 and not frontend:
        # Sec 2 closed form — also serves as an LP cross-check in tests.
        sched = solve_single_source(cspec, frontend=False)
        return sched

    if frontend:
        c, A_ub, b_ub, A_eq, b_eq = build_frontend_lp(cspec)
        x = _run_lp(c, A_ub, b_ub, A_eq, b_eq, solver)
        beta, tf = unpack_frontend(cspec, x)
        sched = Schedule(spec=cspec, beta=beta, finish_time=tf, frontend=True)
        if verify:
            bad = verify_frontend(cspec, beta, tf)
            if bad:
                raise RuntimeError(f"front-end solution violates constraints: {bad[:3]}")
        return sched

    c, A_ub, b_ub, A_eq, b_eq = build_nofrontend_lp(cspec)
    x = _run_lp(c, A_ub, b_ub, A_eq, b_eq, solver)
    beta, TS, TF, tf = unpack_nofrontend(cspec, x)
    sched = Schedule(spec=cspec, beta=beta, finish_time=tf, frontend=False, TS=TS, TF=TF)
    if verify:
        bad = verify_nofrontend(cspec, beta, TS, TF, tf)
        if bad:
            raise RuntimeError(f"no-front-end solution violates constraints: {bad[:3]}")
    return sched


def verify_schedule(sched: Schedule, tol: float = 1e-6) -> list[str]:
    """Re-validate a schedule against the paper's constraint set."""
    if sched.frontend:
        return verify_frontend(sched.spec, sched.beta, sched.finish_time, tol)
    if sched.TS is None or sched.TF is None:
        # closed-form single-source schedule: check Eq 1/2 directly
        spec = sched.spec
        G, A, J = float(spec.G[0]), spec.A, spec.J
        beta = sched.beta[0]
        bad = []
        if abs(beta.sum() - J) > tol * max(1.0, J):
            bad.append("Eq2 violated")
        for i in range(spec.num_processors):
            tf_i = float(spec.R[0]) + beta[: i + 1].sum() * G + beta[i] * A[i]
            if abs(tf_i - sched.finish_time) > tol * max(1.0, sched.finish_time):
                bad.append(f"Eq1 violated at i={i}")
        return bad
    return verify_nofrontend(
        sched.spec, sched.beta, sched.TS, sched.TF, sched.finish_time, tol
    )
