"""Paper Sec 2 — classic single-source DLT closed forms.

Without front-ends (paper Fig 2): processor P_i starts computing after fully
receiving beta_i, the source transmits back-to-back, and all processors finish
simultaneously:

    T_f = sum_{k<=i} beta_k G + beta_i A_i          (Eq 1)
    sum_i beta_i = J                                 (Eq 2)

Consecutive equations give the recursion
    beta_{i+1} (G + A_{i+1}) = beta_i A_i
so beta follows a product chain, closed under normalization — O(M), no LP.

With front-ends the source still transmits back-to-back but P_i computes from
the moment its fraction STARTS arriving, so
    T_f = sum_{k<i} beta_k G + beta_i A_i      (requires A_i >= G for sanity)
giving the recursion beta_{i+1} A_{i+1} = beta_i (A_i - G) ... + beta_i G?
Careful: T_f(i+1)-T_f(i) = beta_i G + beta_{i+1} A_{i+1} - beta_i A_i = 0
    =>  beta_{i+1} = beta_i (A_i - G) / A_{i+1}.
Valid (all beta > 0) iff A_i > G for i < M — i.e. compute is slower than the
link, the paper's standing assumption ("much longer time to compute the data
rather than transfer it").
"""

from __future__ import annotations

import numpy as np

from .types import Schedule, SystemSpec

__all__ = [
    "solve_single_source",
    "finish_time_single_source",
    "single_source_intervals",
]


def single_source_intervals(R0, G, beta_row):
    """Back-to-back transmission intervals of one source's chain.

    ``(TS, TF)`` rows for a source released at ``R0`` with inverse link
    speed ``G`` sending fractions ``beta_row`` to processors 1..M in
    order without idle: ``TF_j = R0 + G * sum_{k<=j} beta_k``.  Works on
    a single row or batched leading axes (broadcasts over ``R0``/``G``).
    Shared by the Sec 2 closed form and the column-reduced Sec 3.2
    formulation's row-1 reconstruction.
    """
    TF = R0 + G * np.cumsum(beta_row, axis=-1)
    return TF - G * beta_row, TF


def solve_single_source(spec: SystemSpec, frontend: bool = False) -> Schedule:
    """Closed-form optimal schedule for a single-source system."""
    if spec.num_sources != 1:
        raise ValueError("solve_single_source requires exactly one source")
    G = float(spec.G[0])
    R0 = float(spec.R[0])
    A = spec.A
    M = spec.num_processors
    J = float(spec.J)

    ratios = np.empty(M)
    ratios[0] = 1.0
    for i in range(M - 1):
        if frontend:
            num = A[i] - G
            if num <= 0:
                # Link faster than compute is violated: fall back to the
                # no-front-end recursion for the remaining chain (the
                # front-end buys nothing if compute outruns the link).
                num = A[i]
                den = G + A[i + 1]
            else:
                den = A[i + 1]
        else:
            num = A[i]
            den = G + A[i + 1]
        ratios[i + 1] = ratios[i] * num / den

    beta = ratios / ratios.sum() * J
    if frontend:
        tf = R0 + beta[0] * A[0]
    else:
        tf = R0 + beta[0] * G + beta[0] * A[0]
    return Schedule(
        spec=spec,
        beta=beta[None, :],
        finish_time=float(tf),
        frontend=frontend,
    )


def finish_time_single_source(spec: SystemSpec, frontend: bool = False) -> float:
    return solve_single_source(spec, frontend=frontend).finish_time
