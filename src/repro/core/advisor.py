"""Cluster-sizing advisor — the paper's Sec 6 trade-off over real TPU fleets.

The paper sweeps "number of processors" against finish time and monetary
cost.  Here the processor is a TPU slice: the advisor takes per-slice-size
step-time estimates (from the roofline analysis of the compiled dry-run),
a step count, and a $/chip-hour rate, and answers the paper's three
questions — what to buy under a cost budget, a deadline, or both — with
the same gradient rule (Eq 18) used to stop adding hardware once marginal
speedup decays.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .dlt.cost import (
    ProcessorSweep,
    TradeoffPlan,
    plan_with_both_budgets,
    plan_with_cost_budget,
    plan_with_time_budget,
)
from .dlt.types import SystemSpec

__all__ = ["SliceCandidate", "ClusterAdvisor", "TPU_V5E_DOLLARS_PER_CHIP_HOUR"]

# Public on-demand list price, us-central (order of magnitude; configurable).
TPU_V5E_DOLLARS_PER_CHIP_HOUR = 1.20


@dataclasses.dataclass(frozen=True)
class SliceCandidate:
    chips: int
    step_time_s: float  # estimated step time at this slice size


class ClusterAdvisor:
    """Sec 6 trade-off plans over TPU slice sizes instead of processor counts."""

    def __init__(
        self,
        candidates: "Sequence[SliceCandidate] | None" = None,
        num_steps: "int | None" = None,
        dollars_per_chip_hour: float = TPU_V5E_DOLLARS_PER_CHIP_HOUR,
        *,
        sweep: "ProcessorSweep | None" = None,
    ):
        if (candidates is None) == (sweep is None):
            raise ValueError("provide either candidates (+ num_steps) or a "
                             "prebuilt sweep, not both")
        if sweep is not None:
            self.sweep = sweep
            self.num_steps = num_steps
            self.rate = dollars_per_chip_hour
            return
        if num_steps is None:
            raise ValueError("num_steps is required with candidates")
        cands = sorted(candidates, key=lambda c: c.chips)
        chips = np.asarray([c.chips for c in cands], dtype=np.int64)
        step_t = np.asarray([c.step_time_s for c in cands])
        job_time = step_t * num_steps
        cost = chips * dollars_per_chip_hour * (job_time / 3600.0)
        # Reuse the paper's sweep container: "m" = chips.
        self.sweep = ProcessorSweep(m=chips, finish_time=job_time, cost=cost)
        self.num_steps = num_steps
        self.rate = dollars_per_chip_hour

    @classmethod
    def from_system_spec(
        cls,
        spec: SystemSpec,
        frontend: bool = True,
        m_max: "int | None" = None,
        engine: str = "batched",
        formulation: "str | None" = None,
    ) -> "ClusterAdvisor":
        """Advisor over an explicit DLT system instead of slice candidates.

        Runs the Sec 6 processor sweep (all prefixes of the canonical
        processor list, one warm-started vmapped session call by default)
        and exposes the same three budget planners over it.  ``spec``
        needs ``C`` for the cost-based plans.  ``formulation`` pins a
        registry formulation.  Compatibility shim over
        :meth:`repro.core.dlt.engine.DLTEngine.advisor` (shared default
        session); sessions with their own config should call
        ``DLTEngine(...).advisor(spec)`` directly.
        """
        from .dlt.engine import get_default_engine

        if engine not in ("batched", "scalar"):
            raise ValueError(
                f"unknown engine {engine!r}: use 'batched' or 'scalar'")
        return get_default_engine().configured(engine=engine).advisor(
            spec, frontend=frontend, m_max=m_max, formulation=formulation)

    def gradient(self) -> np.ndarray:
        """Eq 18 over slice sizes."""
        return self.sweep.gradient()

    def with_cost_budget(self, budget_dollars: float,
                         gradient_threshold: float = 0.06) -> TradeoffPlan:
        return plan_with_cost_budget(self.sweep, budget_dollars,
                                     gradient_threshold)

    def with_time_budget(self, budget_seconds: float) -> TradeoffPlan:
        return plan_with_time_budget(self.sweep, budget_seconds)

    def with_both_budgets(self, budget_dollars: float,
                          budget_seconds: float) -> TradeoffPlan:
        return plan_with_both_budgets(self.sweep, budget_dollars,
                                      budget_seconds)
