"""DLT-driven heterogeneous batch balancing (straggler mitigation).

This is where the paper's scheduler becomes a *training-systems* feature:
data-parallel workers are the paper's processors (A_j = seconds per sample,
measured), input hosts are the sources (G_i = seconds per sample shipped,
R_i = availability), and the global batch is the divisible job J.  Solving
the Sec 3.1/3.2 program yields per-worker load shares that minimize the
step makespan when the fleet is heterogeneous — e.g. a thermally-throttled
or contended worker (a straggler) simply shows up as a larger A_j and
automatically receives less load instead of gating the whole step.

On a homogeneous fleet the optimum degenerates to the uniform split, so
enabling the balancer is free; it only deviates when measurements do.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .dlt import Schedule, SystemSpec, solve

__all__ = ["BatchPlan", "balance_batch", "uniform_makespan"]


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Integer per-worker batch shares plus the schedule they came from."""

    shares: np.ndarray          # (num_workers,) ints, sum == global_batch
    makespan: float             # DLT-optimal step makespan estimate (seconds)
    uniform_makespan: float     # makespan of the naive equal split
    schedule: Schedule          # underlying DLT schedule (canonical order)
    worker_perm: np.ndarray     # canonical index -> original worker index

    @property
    def speedup_vs_uniform(self) -> float:
        return self.uniform_makespan / max(self.makespan, 1e-300)


def uniform_makespan(seconds_per_sample: Sequence[float], global_batch: int) -> float:
    """Step time of the equal split: the slowest worker gates the step."""
    a = np.asarray(seconds_per_sample, dtype=np.float64)
    per = global_batch / len(a)
    return float(np.max(a * per))


def _largest_remainder(fractions: np.ndarray, total: int) -> np.ndarray:
    """Round nonnegative fractions (summing to ``total``) to ints, preserving sum."""
    floors = np.floor(fractions).astype(np.int64)
    short = int(total - floors.sum())
    if short > 0:
        order = np.argsort(-(fractions - floors), kind="stable")
        floors[order[:short]] += 1
    elif short < 0:  # numerical over-count; trim from smallest remainders
        order = np.argsort(fractions - floors, kind="stable")
        k = 0
        while short < 0 and k < len(order):
            if floors[order[k]] > 0:
                floors[order[k]] -= 1
                short += 1
            k += 1
    return floors


def balance_batch(
    seconds_per_sample: Sequence[float],
    global_batch: int,
    source_G: Optional[Sequence[float]] = None,
    source_R: Optional[Sequence[float]] = None,
    frontend: bool = True,
    solver: str = "auto",
) -> BatchPlan:
    """Solve the DLT program for one training step's batch split.

    Args:
      seconds_per_sample: measured per-worker compute time per sample (A_j).
      global_batch: job size J in samples.
      source_G: seconds per sample shipped, per input host.  Defaults to a
        single effectively-infinite-bandwidth source (pure compute balancing).
      source_R: per-source release times (seconds), default all zero.
      frontend: True = workers prefetch (compute overlaps input transfer).
    """
    A = np.asarray(seconds_per_sample, dtype=np.float64)
    if source_G is None:
        # pure compute balancing: one source whose link is far faster than
        # any worker's compute, so communication never binds.
        source_G = [float(A.min()) * 1e-6]
    G = np.asarray(source_G, dtype=np.float64)
    R = np.zeros_like(G) if source_R is None else np.asarray(source_R, np.float64)

    spec = SystemSpec(G=G, R=R, A=A, J=float(global_batch))
    cspec, _, pperm = spec.canonical()
    sched = solve(cspec, frontend=frontend, solver=solver, presorted=True)

    shares_canonical = _largest_remainder(sched.processor_load, global_batch)
    shares = np.zeros_like(shares_canonical)
    shares[pperm] = shares_canonical  # map back to caller's worker order

    return BatchPlan(
        shares=shares,
        makespan=sched.finish_time,
        uniform_makespan=uniform_makespan(A, global_batch),
        schedule=sched,
        worker_perm=pperm,
    )
